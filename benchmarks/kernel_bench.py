"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels run in interpret mode (Python)
— wall-time there is meaningless.  What we CAN measure honestly:

* wall-time of the jnp reference paths (the XLA:CPU-compiled twins) —
  a correctness-speed proxy and a regression canary;
* the kernels' arithmetic/bytes roofline terms on the TPU target,
  derived analytically from the BlockSpec tiling (reported as `derived`).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.roofline.analysis import HW

__all__ = ["rows"]

_HW = HW()


def _time(fn, *args, iters=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.perf_counter() - t0) / iters


def rows():
    out = []
    key = jax.random.PRNGKey(0)

    # flash attention jnp twin
    from repro.models.attention import chunked_attention
    B, S, H, dgl = 1, 1024, 4, 64
    q = jax.random.normal(key, (B, S, H, dgl), jnp.float32)
    k = jax.random.normal(key, (B, S, H, dgl), jnp.float32)
    v = jax.random.normal(key, (B, S, H, dgl), jnp.float32)
    fn = jax.jit(lambda q, k, v: chunked_attention(q, k, v, causal=True, chunk=256))
    t = _time(fn, q, k, v)
    flops = 4 * B * H * S * S * dgl * 0.5  # causal half
    out.append(dict(name="attn_jnp_cpu", us_per_call=t * 1e6,
                    derived=f"tpu_compute_bound_us={flops / _HW.peak_flops * 1e6:.1f}"))

    # ssd scan jnp twin
    from repro.models.mamba2 import ssd_chunked
    b, s, h, p, n = 1, 2048, 8, 64, 64
    x = jax.random.normal(key, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(key, (b, s, h)))
    A = -jnp.ones((h,))
    Bm = jax.random.normal(key, (b, s, n))
    Cm = jax.random.normal(key, (b, s, n))
    fn = jax.jit(lambda *a: ssd_chunked(*a, chunk=128))
    t = _time(fn, x, dt, A, Bm, Cm)
    c = 128
    flops = (s // c) * h * (2 * c * c * n + 2 * c * c * p + 4 * c * p * n) * b
    out.append(dict(name="ssd_jnp_cpu", us_per_call=t * 1e6,
                    derived=f"tpu_compute_bound_us={flops / _HW.peak_flops * 1e6:.2f}"))

    # wkv jnp twin
    from repro.models.rwkv6 import wkv_chunked
    B2, T, H2, N = 1, 1024, 8, 64
    r = jax.random.normal(key, (B2, T, H2, N))
    kk = jax.random.normal(key, (B2, T, H2, N))
    vv = jax.random.normal(key, (B2, T, H2, N))
    w = jax.nn.sigmoid(jax.random.normal(key, (B2, T, H2, N))) * 0.5 + 0.45
    u = jax.random.normal(key, (H2, N))
    fn = jax.jit(lambda *a: wkv_chunked(*a, chunk=64))
    t = _time(fn, r, kk, vv, w, u)
    out.append(dict(name="wkv6_jnp_cpu", us_per_call=t * 1e6,
                    derived="intra-chunk O(c·c·N) dominated"))

    # fused jacobi sweep: jnp shifted-view chain vs fused kernel traffic
    from repro.kernels.stencil.ref import jacobi_sweep_ref
    n2 = 2048
    g = jax.random.normal(key, (n2, n2))
    fn = jax.jit(jacobi_sweep_ref)
    t = _time(fn, g)
    bytes_fused = 2 * n2 * n2 * 4
    bytes_views = 7 * n2 * n2 * 4  # 5 reads + 1 write + temp (paper's form)
    out.append(dict(
        name="jacobi_sweep_jnp_cpu", us_per_call=t * 1e6,
        derived=(f"tpu_mem_bound_us fused={bytes_fused / _HW.hbm_bw * 1e6:.0f} "
                 f"vs views={bytes_views / _HW.hbm_bw * 1e6:.0f} (3.5x)")))
    return out
