"""Generate the EXPERIMENTS.md tables from the dry-run/bench artifacts.

    PYTHONPATH=src python -m benchmarks.make_report > results/tables.md
"""
from __future__ import annotations

import json
from pathlib import Path

D = Path("results/dryrun")


def load(mesh: str, tag: str):
    out = {}
    for f in sorted(D.glob(f"*_{mesh}{'_' + tag if tag else ''}.json")):
        r = json.loads(f.read_text())
        if (r.get("tag") or "") != tag:
            continue
        out[(r["arch"], r["shape"])] = r
    return out


def fmt_s(x):
    if x is None:
        return "—"
    if x >= 100:
        return f"{x:.0f}"
    if x >= 1:
        return f"{x:.1f}"
    return f"{x:.3f}"


def dryrun_table():
    base = load("pod16x16", "")
    multi = load("pod2x16x16", "")
    print("| arch | shape | 16×16 | 2×16×16 | compile(s) | temp bytes/dev |")
    print("|---|---|---|---|---|---|")
    for (a, s), r in base.items():
        m = multi.get((a, s), {})
        st = r["status"]
        st2 = m.get("status", "?")
        comp = r.get("compile_s", "—")
        mem = r.get("memory", {}).get("temp_size_in_bytes")
        mems = f"{mem/1e9:.1f} GB" if mem else "—"
        print(f"| {a} | {s} | {st} | {st2} | {comp} | {mems} |")


def roofline_table():
    cost = load("pod16x16", "cost")
    print("| arch | shape | T_compute(s) | T_memory(s) | T_coll(s) | dominant | MODEL/HLO | note |")
    print("|---|---|---|---|---|---|---|---|")
    for (a, s), r in sorted(cost.items()):
        if r["status"] == "skipped":
            print(f"| {a} | {s} | — | — | — | — | — | skipped: {r['reason'][:40]} |")
            continue
        if r["status"] != "ok":
            print(f"| {a} | {s} | — | — | — | — | — | FAILED |")
            continue
        u = r.get("useful_ratio")
        print(
            f"| {a} | {s} | {fmt_s(r['t_compute'])} | {fmt_s(r['t_memory'])} | "
            f"{fmt_s(r['t_collective'])} | {r['dominant']} | "
            f"{100 * u:.0f}% | |"
        )


def perf_rows(tag_pairs):
    cost = load("pod16x16", "cost")
    print("| cell | variant | T_compute | T_memory | T_coll | dominant | Δdominant |")
    print("|---|---|---|---|---|---|---|")
    for (a, s, tag, label) in tag_pairs:
        base = cost.get((a, s))
        opt = load("pod16x16", tag).get((a, s))
        if not base or base["status"] != "ok":
            continue
        dom = base["dominant"]
        print(f"| {a} × {s} | baseline | {fmt_s(base['t_compute'])} | "
              f"{fmt_s(base['t_memory'])} | {fmt_s(base['t_collective'])} | {dom} | |")
        if opt and opt["status"] == "ok":
            delta = 1 - opt[f"t_{dom}"] / base[f"t_{dom}"]
            print(f"| | {label} | {fmt_s(opt['t_compute'])} | {fmt_s(opt['t_memory'])} | "
                  f"{fmt_s(opt['t_collective'])} | {opt['dominant']} | -{100*delta:.0f}% |")


def overlap_table():
    """Render the measured-overlap artifacts (``results/BENCH_*.json``
    from ``benchmarks.run``): one row per swept variant, then — when the
    run was traced (REPRO_TRACE=1) — the wait-attribution top-K naming
    where the waiting actually went."""
    files = sorted(Path("results").glob("BENCH_*.json"))
    if not files:
        print("  (no BENCH_*.json artifacts — run `python -m benchmarks.run` first)")
        return
    for f in files:
        r = json.loads(f.read_text())
        if r.get("section") in ("serve-load", "serve-plan-cache",
                                "graph-lint"):
            continue  # rendered by their dedicated tables
        print(f"**{r.get('section', f.stem)}** — backend={r.get('backend')}, "
              f"nprocs={r.get('nprocs')}, α={r.get('latency_s', 0) * 1e3:.0f} ms, "
              f"overlap win {r.get('overlap_win', 0):.2f}×\n")
        print("| variant | source | makespan ms | wait% | speedup | comm MB |")
        print("|---|---|---|---|---|---|")
        for label, row in r.get("rows", {}).items():
            print(f"| {label} | {row['source']} | {row['makespan_s'] * 1e3:.1f} | "
                  f"{row['wait_fraction'] * 100:.1f}% | {row['speedup']:.2f} | "
                  f"{row['comm_bytes'] / 1e6:.2f} |")
        att = r.get("attribution")
        if not att:
            print("\n(untraced run — re-run with REPRO_TRACE=1 for wait attribution)\n")
            continue
        print(f"\nWait attribution ({att['nworkers']} workers, "
              f"{att['elapsed_s'] * 1e3:.1f} ms traced drain, trace wait "
              f"{att['wait_fraction'] * 100:.1f}% vs measured "
              f"{att['measured_wait_fraction'] * 100:.1f}%):\n")
        print("| # | wait source | wait ms | spans | msgs | mean post→deliver |")
        print("|---|---|---|---|---|---|")
        for i, off in enumerate(att.get("top", []), 1):
            lat = off.get("msg_latency")
            print(f"| {i} | {off['group']} | {off['seconds'] * 1e3:.2f} | "
                  f"{off['n_spans']} | {off.get('n_msgs') or '—'} | "
                  f"{f'{lat * 1e3:.2f} ms' if lat else '—'} |")
        print()


def serve_load_table():
    """Render ``results/BENCH_serve_load.json`` (from
    ``benchmarks.serve_load``): serialized vs concurrent cone drains
    under multi-tenant load, with the latency quantiles."""
    f = Path("results/BENCH_serve_load.json")
    if not f.exists():
        print("  (no BENCH_serve_load.json — run `python -m benchmarks.serve_load`)")
        return
    r = json.loads(f.read_text())
    print(f"**serve-load** — {r['clients']} clients, {r['requests']} requests, "
          f"{r['nprocs']} procs, α={r['latency_s'] * 1e3:.0f} ms, "
          f"concurrent/serialized throughput {r['speedup']:.2f}×, "
          f"corrupted results: {r['corruption']}\n")
    print("| variant | inflight | elapsed s | req/s | p50 ms | p95 ms | p99 ms | max ms | rejected |")
    print("|---|---|---|---|---|---|---|---|---|")
    for label in ("serialized", "concurrent"):
        v = r["variants"].get(label)
        if not v:
            continue
        print(f"| {label} | {v['max_inflight']} | {v['elapsed_s']:.1f} | "
              f"{v['throughput_rps']:.1f} | {v['latency_p50_s'] * 1e3:.1f} | "
              f"{v['latency_p95_s'] * 1e3:.1f} | {v['latency_p99_s'] * 1e3:.1f} | "
              f"{v['latency_max_s'] * 1e3:.1f} | {v['n_rejected']} |")
    print(f"\n(p99 budget: {r['p99_budget_s'] * 1e3:.1f} ms — "
          f"{r['variants']['concurrent']['latency_p99_s'] * 1e3:.1f} ms observed)\n")


def serve_plan_cache_table():
    """Render ``results/BENCH_serve_plan_cache.json`` (from
    ``benchmarks.serve_load --suite plan-cache``): the repeated-shape
    workload gating the plan-shape cache and off-lock planning."""
    f = Path("results/BENCH_serve_plan_cache.json")
    if not f.exists():
        print("  (no BENCH_serve_plan_cache.json — run "
              "`python -m benchmarks.serve_load --suite plan-cache`)")
        return
    r = json.loads(f.read_text())
    print(f"**serve-plan-cache** — {r['clients']} clients, "
          f"{r['requests']} repeated-shape requests, "
          f"α={r['latency_s'] * 1e3:.0f} ms: "
          f"{r['speedup_vs_serialized']:.2f}× vs serialized, "
          f"hit rate {r['hit_rate'] * 100:.1f}%, "
          f"lock-hold reduction {r['lock_hold_reduction']:.2f}×, "
          f"corrupted results: {r['corruption']}\n")
    print("| variant | req/s | p50 ms | p99 ms | lock hold µs (mean) "
          "| plan+submit µs (mean) | cache hit % | batched cones |")
    print("|---|---|---|---|---|---|---|---|")
    for label in ("serialized", "concurrent-nocache", "concurrent-cache"):
        v = r["variants"].get(label)
        if not v:
            continue
        pc = v.get("plan_cache")
        hit = f"{pc['hit_rate'] * 100:.1f}" if pc else "—"
        b = v.get("batcher")
        merged = str(b["n_merged"]) if b else "—"
        print(f"| {label} | {v['throughput_rps']:.1f} | "
              f"{v['latency_p50_s'] * 1e3:.1f} | "
              f"{v['latency_p99_s'] * 1e3:.1f} | "
              f"{v['lock_hold_mean_s'] * 1e6:.1f} | "
              f"{v['plan_mean_s'] * 1e6:.1f} | {hit} | {merged} |")
    print()


def graph_lint_table():
    """Render ``results/BENCH_graph_lint.json`` (from
    ``python -m repro.analysis``): one row per linted program, with the
    verifier counters and the race-oracle precision statistic for the
    in-process stencil run."""
    f = Path("results/BENCH_graph_lint.json")
    if not f.exists():
        print("  (no BENCH_graph_lint.json — run `python -m repro.analysis`)")
        return
    r = json.loads(f.read_text())
    print("| program | ok | seconds | flushes verified | race checks "
          "| diagnostics | precision |")
    print("|---|---|---|---|---|---|---|")
    for row in r.get("results", []):
        nf = row.get("n_flushes_verified")
        nr = row.get("n_race_checks")
        nd = row.get("n_diagnostics")
        p = row.get("precision")
        prec = f"{p * 100:.1f}%" if p is not None else "—"
        print(f"| {row['program']} | {'✓' if row['ok'] else 'FAILED'} | "
              f"{row['seconds']:.1f} | {nf if nf is not None else '—'} | "
              f"{nr if nr is not None else '—'} | "
              f"{nd if nd is not None else '—'} | {prec} |")
    fps = [row for row in r.get("results", [])
           if row.get("n_key_conflicts")]
    for row in fps:
        print(f"\n({row['program']}: {row['n_region_false_positives']} of "
              f"{row['n_key_conflicts']} key-level cone conflicts were "
              f"region-level false positives — the gap a sub-block cone "
              f"footprint would close)")
    print()


if __name__ == "__main__":
    import sys

    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "dryrun"):
        print("### Dry-run matrix\n")
        dryrun_table()
        print()
    if which in ("all", "roofline"):
        print("### Roofline (corrected cost probes, single-pod)\n")
        roofline_table()
        print()
    if which in ("all", "overlap"):
        print("### Measured overlap & wait attribution\n")
        overlap_table()
        print()
    if which in ("all", "serve"):
        print("### Multi-tenant serving load\n")
        serve_load_table()
        print()
        print("### Plan-shape cache under repeated-shape load\n")
        serve_plan_cache_table()
        print()
    if which in ("all", "graph_lint"):
        print("### Graph lint (static verification)\n")
        graph_lint_table()
        print()
    if which in ("all", "perf"):
        print("### Perf iterations\n")
        perf_rows([
            ("mistral-large-123b", "train_4k", "opt1", "+vp-loss +act-shard"),
            ("yi-34b", "prefill_32k", "opt1", "+vp-loss +act-shard"),
            ("deepseek-v2-lite-16b", "train_4k", "opt1", "+vp-loss +act-shard"),
        ])
